//! Regenerates the paper's **Table 1**: quantized error rates for
//! {synth-MNIST x LeNet5, synth-CIFAR10 x {VGG7, DenseNet},
//!  synth-CIFAR100 x {VGG11, VGG16}} under SYMOG and the comparator
//! methods (BC, TWN, BR) plus the FP32 baseline.
//!
//! Every method follows the paper's protocol: FP32 pretraining, then the
//! quantized method initialized from the pretrained weights. The absolute
//! numbers differ from the paper (synthetic data, width-scaled models —
//! DESIGN.md §Substitutions); the comparison that must reproduce is the
//! ORDERING: SYMOG ~ FP32 baseline, SYMOG < TWN/BR < BC.
//!
//!   SYMOG_BENCH_BUDGET=smoke|small|full cargo bench --bench table1

use anyhow::Result;
use symog::bench::Budget;
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::report::{render_table1, Table1Row};
use symog::runtime::Runtime;

struct Block {
    dataset: Preset,
    model: &'static str,
    artifact_model: &'static str, // tag fragment: "<model>-<method>-<dataset>-<w>"
    suffix: &'static str,
    methods: &'static [&'static str],
    augment: bool,
}

const BLOCKS: &[Block] = &[
    Block {
        dataset: Preset::SynthMnist,
        model: "LeNet5",
        artifact_model: "lenet5",
        suffix: "synth-mnist-w1-b2",
        methods: &["symog", "bc", "twn", "br"],
        augment: false,
    },
    Block {
        dataset: Preset::SynthCifar10,
        model: "VGG7 (0.25x)",
        artifact_model: "vgg7",
        suffix: "synth-cifar10-w0.25-b2",
        methods: &["symog", "twn"],
        augment: true,
    },
    Block {
        // depth-40 variant: the L=76 graph compiles too slowly on CPU XLA
        // for the bench loop; same architecture family (DESIGN.md)
        dataset: Preset::SynthCifar10,
        model: "DenseNet-40 (0.5x)",
        artifact_model: "densenet40",
        suffix: "synth-cifar10-w0.5-b2",
        methods: &["symog"],
        augment: true,
    },
    Block {
        dataset: Preset::SynthCifar100,
        model: "VGG11 (0.25x)",
        artifact_model: "vgg11",
        suffix: "synth-cifar100-w0.25-b2",
        methods: &["symog", "br"],
        augment: true,
    },
    Block {
        dataset: Preset::SynthCifar100,
        model: "VGG16 (0.25x)",
        artifact_model: "vgg16",
        suffix: "synth-cifar100-w0.25-b2",
        methods: &["symog"],
        augment: true,
    },
];

fn main() -> Result<()> {
    let budget = Budget::from_env();
    let (epochs, train_n, test_n, steps) = budget.training_scale();
    // optional comma-separated dataset filter, e.g.
    // SYMOG_BENCH_BLOCKS=synth-cifar100 to re-run one block
    let filter = std::env::var("SYMOG_BENCH_BLOCKS").unwrap_or_default();
    println!("== Table 1 regeneration ({budget:?}: {epochs} epochs, {train_n} train) ==\n");
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let mut rows: Vec<Table1Row> = Vec::new();

    for block in BLOCKS {
        if !filter.is_empty() && !filter.split(',').any(|f| f == block.dataset.name()) {
            continue;
        }
        println!("--- {} on {} ---", block.model, block.dataset.name());
        let (train, test) = block.dataset.load(train_n, test_n, 0);
        let mk = |method: &str, lambda_kind: &str| Experiment {
            name: format!("{}-{}", block.artifact_model, method),
            artifact: format!("{}-{}-{}", block.artifact_model, method, block.suffix),
            dataset: block.dataset,
            train_n,
            test_n,
            epochs,
            lambda_kind: lambda_kind.into(),
            augment: block.augment,
            steps_per_epoch: steps,
            verbose: false,
            ..Default::default()
        };
        // FP32 pretrain/baseline
        let baseline = mk("baseline", "off");
        let base_art = driver::load_artifact(&rt, &baseline, &root)?;
        let params = base_art.manifest.num_params();
        let base = driver::run_experiment(&base_art, &baseline, &train, &test)?;
        println!("  baseline (fp32): {:.2}%", base.best_f_error * 100.0);

        // each quantized method, initialized from the pretrained weights
        let tmp = std::env::temp_dir().join(format!("symog_t1_{}.ckpt", block.artifact_model));
        base.final_ckpt.write(&tmp)?;
        for &method in block.methods {
            let lambda_kind = match method {
                "symog" | "br" => "exp",
                _ => "off",
            };
            let mut exp = mk(method, lambda_kind);
            exp.init_from = Some(tmp.clone());
            let art = match driver::load_artifact(&rt, &exp, &root) {
                Ok(a) => a,
                Err(e) => {
                    println!("  {method}: skipped ({e:#})");
                    continue;
                }
            };
            let res = driver::run_experiment(&art, &exp, &train, &test)?;
            println!("  {method}: {:.2}%", res.best_q_error * 100.0);
            rows.push(Table1Row {
                dataset: block.dataset.name().into(),
                method: method.to_uppercase(),
                model: block.model.into(),
                params,
                bits: if method == "bc" { "1" } else { "2" }.into(),
                fixed_point: method == "symog" || method == "bc",
                epochs,
                error: res.best_q_error,
            });
        }
        rows.push(Table1Row {
            dataset: block.dataset.name().into(),
            method: "Baseline".into(),
            model: block.model.into(),
            params,
            bits: "32".into(),
            fixed_point: false,
            epochs,
            error: base.best_f_error,
        });
        std::fs::remove_file(&tmp).ok();
        println!();
    }

    let rendered = render_table1(&rows);
    println!("{rendered}");
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/table1.md", &rendered)?;
    println!("-> results/table1.md");
    Ok(())
}
