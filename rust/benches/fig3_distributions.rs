//! Regenerates the paper's **Figure 1 and Figure 3**: the weight
//! distribution of VGG11 layers 1/4/7 evolving from a unimodal Gaussian
//! (pretrained) to three separated Gaussian modes over SYMOG training.
//!
//!   SYMOG_BENCH_BUDGET=smoke|small|full cargo bench --bench fig3_distributions
//!
//! Emits results/fig3_layer{n}.csv (epoch x histogram) + terminal sparklines.

use anyhow::Result;
use symog::bench::Budget;
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let budget = Budget::from_env();
    let (epochs, train_n, test_n, steps) = budget.training_scale();
    println!("== Figure 3 regeneration ({budget:?}) ==");
    let rt = Runtime::cpu()?;
    // the paper plots layers 1, 4 and 7 of VGG11 (1-based conv index);
    // qidx 0/3/6 are the corresponding quantized-layer indices here
    let hist_layers = vec![0usize, 3, 6];
    let hist_epochs: Vec<u32> = {
        let mut v = vec![0];
        for k in 1..=4u32 {
            v.push(epochs * k / 4);
        }
        v.dedup();
        v
    };
    // the paper's protocol: weight-decay pretraining, then SYMOG — the
    // epoch-0 panel of Figure 3 is the *pretrained* unimodal distribution
    let baseline = Experiment {
        name: "fig3-pretrain".into(),
        artifact: "vgg11-baseline-synth-cifar100-w0.25-b2".into(),
        dataset: Preset::SynthCifar100,
        train_n,
        test_n,
        epochs: (epochs / 2).max(1),
        lambda_kind: "off".into(),
        augment: true,
        steps_per_epoch: steps,
        verbose: false,
        ..Default::default()
    };
    let exp = Experiment {
        name: "fig3".into(),
        artifact: "vgg11-symog-synth-cifar100-w0.25-b2".into(),
        epochs,
        lambda_kind: "exp".into(),
        hist_epochs: hist_epochs.clone(),
        hist_layers: hist_layers.clone(),
        verbose: true,
        ..baseline.clone()
    };
    let (train, test) = exp.dataset.load(train_n, test_n, 0);
    println!("(pretraining fp32 for {} epochs first)", baseline.epochs);
    let (_, result) =
        driver::pretrain_then_run(&rt, &baseline, &exp, &artifacts_root(), &train, &test)?;

    std::fs::create_dir_all("results").ok();
    for (qidx, series) in &result.outcome.histograms {
        let paper_layer = qidx + 1;
        println!("\nLayer-{paper_layer} weight distribution (Figure 3 panel):");
        let mut grid = symog::report::plot::HistogramGrid::new(&format!(
            "Figure 3 — VGG11 layer {paper_layer} weight distribution"
        ));
        for (e, h) in series.epochs.iter().zip(&series.hists) {
            println!("  epoch {e:3}  {}", h.sparkline());
            grid.panel(&format!("epoch {e}"), h.lo, h.hi, &h.counts);
        }
        let path = format!("results/fig3_layer{paper_layer}.csv");
        std::fs::write(&path, series.to_csv())?;
        let svg_path = format!("results/fig3_layer{paper_layer}.svg");
        std::fs::write(&svg_path, grid.to_svg())?;
        println!("  -> {path}, {svg_path}");
    }
    println!(
        "\nfinal quantized error {:.2}% (float {:.2}%)",
        result.best_q_error * 100.0,
        result.best_f_error * 100.0
    );
    Ok(())
}
