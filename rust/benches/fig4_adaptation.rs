//! Regenerates the paper's **Figure 4**: per-epoch mode-switch rates of
//! VGG11 layers during SYMOG training, with weight clipping (upper panel)
//! vs without (lower panel). The paper's headline: clipping raises the
//! early adaptation rate (~22% vs ~8% in layer 7) and improves the final
//! error.
//!
//!   SYMOG_BENCH_BUDGET=smoke|small|full cargo bench --bench fig4_adaptation

use anyhow::Result;
use symog::bench::Budget;
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let budget = Budget::from_env();
    let (epochs, train_n, test_n, steps) = budget.training_scale();
    println!("== Figure 4 regeneration ({budget:?}) ==");
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let base = Experiment {
        name: "fig4".into(),
        artifact: String::new(),
        dataset: Preset::SynthCifar100,
        train_n,
        test_n,
        epochs,
        augment: true,
        steps_per_epoch: steps,
        track_modes: true,
        verbose: false,
        ..Default::default()
    };
    let (train, test) = Preset::SynthCifar100.load(train_n, test_n, 0);

    // shared fp32 pretraining (the paper inits both variants identically)
    let baseline = Experiment {
        name: "fig4-pretrain".into(),
        artifact: "vgg11-baseline-synth-cifar100-w0.25-b2".into(),
        epochs: (epochs / 2).max(1),
        lambda_kind: "off".into(),
        track_modes: false,
        ..base.clone()
    };
    println!("(pretraining fp32 for {} epochs first)", baseline.epochs);
    let base_art = driver::load_artifact(&rt, &baseline, &root)?;
    let pretrained = driver::run_experiment(&base_art, &baseline, &train, &test)?;
    let tmp = std::env::temp_dir().join("symog_fig4_pretrain.ckpt");
    pretrained.final_ckpt.write(&tmp)?;

    let mut panels = Vec::new();
    for (label, artifact, csv) in [
        ("with clipping", "vgg11-symog-synth-cifar100-w0.25-b2", "results/fig4_with_clip.csv"),
        (
            "without clipping",
            "vgg11-symog-synth-cifar100-w0.25-b2-noclip",
            "results/fig4_without_clip.csv",
        ),
    ] {
        println!("\n--- SYMOG {label} ---");
        let exp = Experiment {
            artifact: artifact.into(),
            init_from: Some(tmp.clone()),
            ..base.clone()
        };
        let art = driver::load_artifact(&rt, &exp, &root)?;
        let result = driver::run_experiment(&art, &exp, &train, &test)?;
        let tracker = result.outcome.tracker.as_ref().unwrap();
        std::fs::create_dir_all("results").ok();
        std::fs::write(csv, tracker.to_csv())?;
        println!("  -> {csv}");
        // per-epoch mean + the paper's "first half" aggregate
        let rates: Vec<f32> = result.outcome.log.epochs.iter().map(|e| e.switch_rate).collect();
        let half = rates.len() / 2;
        let first_half_mean = symog::util::mean(&rates[..half.max(1)]);
        println!(
            "  mean switch rate, first half of training: {:.1}%",
            first_half_mean * 100.0
        );
        for (i, r) in rates.iter().enumerate() {
            println!("  epoch {:3}  {:5.1}%  {}", i + 1, r * 100.0,
                     "#".repeat((r * 200.0) as usize));
        }
        panels.push((label, first_half_mean, result.best_q_error));
    }

    // SVG: per-layer switch-rate curves, one chart per clipping variant
    for (label, csv, svg) in [
        ("with clipping", "results/fig4_with_clip.csv", "results/fig4_with_clip.svg"),
        ("without clipping", "results/fig4_without_clip.csv", "results/fig4_without_clip.svg"),
    ] {
        if let Ok(data) = std::fs::read_to_string(csv) {
            let mut chart = symog::report::plot::LineChart::new(
                &format!("Figure 4 — mode switches per epoch ({label})"),
                "epoch",
                "% weights switching mode",
            );
            let rows: Vec<Vec<f32>> = data
                .lines()
                .skip(1)
                .map(|l| l.split(',').filter_map(|v| v.parse().ok()).collect())
                .collect();
            let n_layers = rows.first().map(|r| r.len().saturating_sub(1)).unwrap_or(0);
            for li in (0..n_layers).step_by(3) {
                // plot every 3rd layer to keep the legend readable
                let pts: Vec<(f32, f32)> = rows
                    .iter()
                    .skip(1) // epoch 0 is the baseline record
                    .map(|r| (r[0], r[li + 1] * 100.0))
                    .collect();
                chart.series(&format!("layer {}", li + 1), pts);
            }
            std::fs::write(svg, chart.to_svg())?;
            println!("  -> {svg}");
        }
    }

    println!("\n== Figure 4 summary ==");
    println!("{:<20} {:>22} {:>18}", "variant", "first-half switch", "final q-error");
    for (label, rate, err) in &panels {
        println!("{:<20} {:>21.1}% {:>17.2}%", label, rate * 100.0, err * 100.0);
    }
    let (with, without) = (&panels[0], &panels[1]);
    println!(
        "\npaper's claim check: clipping raises early adaptation ({:.1}% vs {:.1}%) -> {}",
        with.1 * 100.0,
        without.1 * 100.0,
        if with.1 > without.1 { "REPRODUCED" } else { "NOT reproduced at this budget" }
    );
    Ok(())
}
