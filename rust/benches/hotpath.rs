//! Hot-path microbenchmarks (the §Perf instrument): where does a training
//! step spend its time, and how fast are the L3 substrates?
//!
//!   cargo bench --bench hotpath
//!
//! Sections (SYMOG_HOTPATH picks them; comma-separated lists compose):
//!   1. `gemm` — integer conv/dense: naive loops vs im2col + blocked GEMM
//!      on VGG7-shaped layers, plus interpret-vs-planned whole-model
//!      forwards (`ExecPlan` arena + fused epilogues vs the per-call GEMM
//!      walk), plus f32 training steps (conv fwd+bwd) naive-vs-GEMM on
//!      the same shapes. Bit-identity asserted for the integer kernels.
//!   2. `serve` — serving throughput: closed-loop client threads through
//!      `serve::Server` (dynamic micro-batching, per-request isolation)
//!      vs solo batch-1 planned forwards of the identical corpus
//!      (bit-identity asserted before timing).
//!   2b. `bitslice` — the bit-sliced AND/popcount kernel on 2-/3-bit
//!      conv/dense shapes vs the naive loops, with engagement asserted
//!      (`kernel_name` must resolve to "bitslice") and the active
//!      `SYMOG_SIMD` dispatch level printed.
//!   2c. `pool` — fan-out dispatch itself: spawn-per-call scoped threads
//!      (the pre-persistent-pool implementation, kept verbatim here as
//!      the baseline) vs `util::pool`'s persistent parked workers, on
//!      dispatch-dominated chunk sizes, with zero steady-state thread
//!      spawns asserted via the pool counters. Sections 1+2+2b+2c emit
//!      BENCH_hotpath.json at the repo root so the perf trajectory is
//!      tracked PR over PR (CI gates on "gemm,serve,bitslice,pool").
//!   3. `runtime` — train-step latency breakdown (batch assembly /
//!      literal upload / execute) for the lenet5 artifact (the L3 target
//!      is <10% of step time outside `execute`) plus eval and
//!      integer-engine throughput (`engine` for just the latter).
//!   4. `substrates` — quantizer, solver, mode tracking, synth-data.

use std::collections::BTreeMap;

use anyhow::Result;
use symog::bench::{bench, bench_budgeted, fmt_time, Stats};
use symog::coordinator::{ModeTracker, Trainer};
use symog::data::{AugmentConfig, BatchIter, Preset};
use symog::driver::artifacts_root;
use symog::fixedpoint;
use symog::inference::{
    conv2d, conv2d_naive, dense, dense_naive, Backend, IntModel, OpCounts, QTensor, QWeight,
};
use symog::runtime::{literal_f32, literal_i32, literal_scalar_f32, run, Runtime};
use symog::testing::models;
use symog::train::ops as tops;
use symog::util::json::Json;
use symog::util::rng::Rng;

fn main() -> Result<()> {
    println!("== SYMOG hot-path benchmarks ==\n");
    // SYMOG_HOTPATH=gemm|serve|bitslice|pool|substrates|runtime|engine
    // picks sections; comma-separated lists compose (CI gates on
    // "gemm,serve,bitslice,pool")
    let section = std::env::var("SYMOG_HOTPATH").unwrap_or_default();
    let want = |name: &str| section.is_empty() || section.split(',').any(|s| s.trim() == name);
    let mut report: Vec<Stats> = Vec::new();
    let mut cases_json: Vec<Json> = Vec::new();
    let mut top: BTreeMap<String, Json> = BTreeMap::new();

    if want("gemm") {
        gemm_benches(&mut report, &mut cases_json, &mut top)?;
    }
    if want("serve") {
        serve_benches(&mut report, &mut cases_json)?;
    }
    if want("bitslice") {
        bitslice_benches(&mut report, &mut cases_json)?;
    }
    if want("pool") {
        pool_dispatch_benches(&mut report, &mut cases_json);
    }
    if want("gemm") || want("serve") || want("bitslice") || want("pool") {
        // one report for every gated ratio family (bench_check reads this)
        top.insert("bench".to_string(), Json::Str("hotpath".to_string()));
        let workers = symog::util::pool::default_workers();
        top.insert("workers".to_string(), json_num(workers as f64));
        top.insert("cases".to_string(), Json::Arr(std::mem::take(&mut cases_json)));
        let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_hotpath.json");
        std::fs::write(&out, Json::Obj(std::mem::take(&mut top)).to_string() + "\n")?;
        println!("-> {}", out.display());
    }
    if want("substrates") {
        substrate_benches(&mut report);
    }
    if want("runtime") || want("engine") {
        // "engine" alone (or composed, e.g. "gemm,engine") runs only the
        // integer-engine throughput part; "runtime" runs the full section
        let engine_only = want("engine") && !want("runtime");
        if let Err(e) = runtime_benches(&mut report, engine_only) {
            println!("(runtime benches skipped: {e:#})");
        }
    }

    println!("\n== summary ==");
    for s in &report {
        println!("{}", s.row());
    }
    std::fs::create_dir_all("results").ok();
    let mut csv = String::from("name,iters,mean_s,median_s,p95_s,min_s\n");
    for s in &report {
        csv.push_str(&format!(
            "{},{},{},{},{},{}\n",
            s.name, s.iters, s.mean_s, s.median_s, s.p95_s, s.min_s
        ));
    }
    std::fs::write("results/hotpath.csv", csv)?;
    println!("-> results/hotpath.csv");
    Ok(())
}

/// One naive-vs-GEMM conv comparison case (stride-1 SAME, VGG7-shaped).
struct ConvCase {
    name: &'static str,
    n: usize,
    h: usize,
    cin: usize,
    cout: usize,
    n_bits: u32,
    /// weight zero fraction for 2-bit cases (SYMOG's center mode)
    zero_frac: f32,
}

const CONV_CASES: &[ConvCase] = &[
    // VGG7 mid-stack shape, 8-bit weights: the multiply micro-kernel
    ConvCase {
        name: "conv3 16x16 64->64 w8",
        n: 32,
        h: 16,
        cin: 64,
        cout: 64,
        n_bits: 8,
        zero_frac: 0.0,
    },
    // VGG7 top-stack shape, uniform ternary (2-bit SYMOG)
    ConvCase {
        name: "conv5 8x8 128->128 w2",
        n: 32,
        h: 8,
        cin: 128,
        cout: 128,
        n_bits: 2,
        zero_frac: 0.34,
    },
    // same shape, sparse ternary: the pure add/sub plan engages
    ConvCase {
        name: "conv5 8x8 128->128 w2-sparse",
        n: 32,
        h: 8,
        cin: 128,
        cout: 128,
        n_bits: 2,
        zero_frac: 0.8,
    },
];

fn conv_weights(rng: &mut Rng, numel: usize, n_bits: u32, zero_frac: f32, delta: f32) -> Vec<f32> {
    (0..numel)
        .map(|_| {
            if n_bits == 2 {
                if rng.bool(zero_frac) {
                    0.0
                } else if rng.bool(0.5) {
                    delta
                } else {
                    -delta
                }
            } else {
                rng.normal() * 8.0 * delta
            }
        })
        .collect()
}

fn json_num(v: f64) -> Json {
    Json::Num(v)
}

/// Bit-sliced AND/popcount kernel on 2-/3-bit conv/dense shapes vs the
/// naive loops. Engagement is asserted before timing — every case must
/// resolve to the "bitslice" kernel, so a selection regression fails the
/// bench instead of silently timing the multiply path — and bit-identity
/// is gated exactly like the gemm section.
fn bitslice_benches(report: &mut Vec<Stats>, cases_json: &mut Vec<Json>) -> Result<()> {
    use symog::inference::kernel_name;
    use symog::kernels::bitslice::simd_level;
    println!("--- bit-sliced popcount kernel (SIMD level: {}) ---", simd_level().name());
    let delta = 0.25f32;

    // (name, h, cin, cout, n_bits, zero_frac): the uniform-ternary conv
    // and dense shapes the gemm section also runs (there they route to
    // this kernel too, post cost race) plus a 3-bit two-plane conv
    let conv_cases: &[(&str, usize, usize, usize, u32, f32)] = &[
        ("bitslice conv3 8x8 128->128 w2", 8, 128, 128, 2, 0.34),
        ("bitslice conv3 16x16 64->64 w3", 16, 64, 64, 3, 0.0),
    ];
    for &(name, h, cin, cout, n_bits, zero_frac) in conv_cases {
        let mut rng = Rng::new(0xB175);
        let (n, k) = (32usize, 3usize);
        let xs: Vec<f32> = (0..n * h * h * cin).map(|_| rng.normal()).collect();
        let ws = conv_weights(&mut rng, k * k * cin * cout, n_bits, zero_frac, delta);
        let qx = QTensor::from_f32(&xs, [n, h, h, cin], 8);
        let qw = QWeight::encode(&ws, [k, k, cin, cout], delta, n_bits);
        assert_eq!(
            kernel_name(&qw, k * k * cin, cout),
            "bitslice",
            "{name}: popcount kernel did not engage"
        );
        let macs = (n * h * h * cout * k * k * cin) as u64;

        // correctness gate before timing anything
        let mut cg = OpCounts::default();
        let mut cn = OpCounts::default();
        let got = conv2d(&qx, &qw, 1, true, &mut cg);
        let want = conv2d_naive(&qx, &qw, 1, true, &mut cn);
        assert_eq!(got.data, want.data, "{name}: bit-sliced output differs from naive");
        assert_eq!(cg, cn, "{name}: op counts differ");

        let naive = bench(&format!("naive {name}"), 1, 3, || {
            let mut c = OpCounts::default();
            std::hint::black_box(conv2d_naive(&qx, &qw, 1, true, &mut c));
        });
        let fast = bench(&format!("bits  {name}"), 2, 10, || {
            let mut c = OpCounts::default();
            std::hint::black_box(conv2d(&qx, &qw, 1, true, &mut c));
        });
        let speedup = naive.median_s / fast.median_s;
        println!(
            "{}\n{}\n  -> {:.1} GMAC/s vs {:.1} GMAC/s: {:.2}x speedup",
            naive.row(),
            fast.row(),
            macs as f64 / naive.median_s / 1e9,
            macs as f64 / fast.median_s / 1e9,
            speedup,
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(name.to_string()));
        o.insert("kind".to_string(), Json::Str("bitslice".to_string()));
        o.insert("batch".to_string(), json_num(n as f64));
        o.insert("macs".to_string(), json_num(macs as f64));
        o.insert("n_bits".to_string(), json_num(n_bits as f64));
        o.insert("naive_s".to_string(), json_num(naive.median_s));
        o.insert("gemm_s".to_string(), json_num(fast.median_s));
        o.insert("speedup".to_string(), json_num(speedup));
        o.insert("bit_identical".to_string(), Json::Bool(true));
        cases_json.push(Json::Obj(o));
        report.push(naive);
        report.push(fast);
    }

    // dense classifier-head shape, uniform ternary
    let (dn, fi, fo) = (64usize, 2048usize, 512usize);
    let mut rng = Rng::new(0xB175D);
    let xs: Vec<f32> = (0..dn * fi).map(|_| rng.normal()).collect();
    let ws = conv_weights(&mut rng, fi * fo, 2, 0.34, delta);
    let qx = QTensor::from_f32(&xs, [dn, 1, 1, fi], 8);
    let qw = QWeight::encode(&ws, [fi, fo, 1, 1], delta, 2);
    assert_eq!(kernel_name(&qw, fi, fo), "bitslice", "dense: popcount kernel did not engage");
    let macs = (dn * fi * fo) as u64;
    let mut cg = OpCounts::default();
    let mut cn = OpCounts::default();
    assert_eq!(dense(&qx, &qw, &mut cg).data, dense_naive(&qx, &qw, &mut cn).data);
    assert_eq!(cg, cn);
    let naive = bench("naive bitslice dense 2048->512 w2", 1, 5, || {
        let mut c = OpCounts::default();
        std::hint::black_box(dense_naive(&qx, &qw, &mut c));
    });
    let fast = bench("bits  bitslice dense 2048->512 w2", 2, 10, || {
        let mut c = OpCounts::default();
        std::hint::black_box(dense(&qx, &qw, &mut c));
    });
    let speedup = naive.median_s / fast.median_s;
    println!("{}\n{}\n  -> {:.2}x speedup", naive.row(), fast.row(), speedup);
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str("bitslice dense 2048->512 w2".to_string()));
    o.insert("kind".to_string(), Json::Str("bitslice".to_string()));
    o.insert("batch".to_string(), json_num(dn as f64));
    o.insert("macs".to_string(), json_num(macs as f64));
    o.insert("n_bits".to_string(), json_num(2.0));
    o.insert("naive_s".to_string(), json_num(naive.median_s));
    o.insert("gemm_s".to_string(), json_num(fast.median_s));
    o.insert("speedup".to_string(), json_num(speedup));
    o.insert("bit_identical".to_string(), Json::Bool(true));
    cases_json.push(Json::Obj(o));
    report.push(naive);
    report.push(fast);
    Ok(())
}

/// Spawn-per-call `par_chunks_mut` — the pre-persistent-pool scoped
/// implementation, kept verbatim as the dispatch baseline. Same chunk
/// layout formula as `util::pool::par_chunks_mut`, so the two sides of
/// the ratio do identical work and differ only in dispatch.
fn spawn_chunks_mut<T: Send, F>(data: &mut [T], workers: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    let chunk = n.div_ceil(workers);
    if chunk >= n {
        f(0, data);
        return;
    }
    std::thread::scope(|s| {
        for (ci, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * chunk, part));
        }
    });
}

/// Dispatch overhead head-to-head: spawn/join fresh OS threads per call
/// (the pre-PR-8 implementation above) vs the persistent parked pool, on
/// deliberately tiny chunk workloads so the ratio measures dispatch, not
/// compute. Bit-identity of the two fan-outs is asserted before timing,
/// and the pool counters must show zero thread spawns across the timed
/// reps (the steady-state contract); the ratio lands in
/// BENCH_hotpath.json as kind `pool_dispatch` for the bench_check gate.
fn pool_dispatch_benches(report: &mut Vec<Stats>, cases_json: &mut Vec<Json>) {
    use symog::util::pool;

    println!("--- fan-out dispatch (spawn-per-call vs persistent pool) ---");
    // per-element transform, derived from the global index so any chunk
    // layout bug would show up as a bit difference
    let step = |off: usize, chunk: &mut [u64]| {
        for (j, x) in chunk.iter_mut().enumerate() {
            *x = x.wrapping_add(((off + j) as u64).wrapping_mul(0x9E37_79B9));
        }
    };
    // (name, fanout, elems): the fanout is fixed, not host-derived — the
    // scoped baseline spawned exactly `fanout` threads whatever the core
    // count, so the ratio stays comparable across machines; REPS
    // dispatches per timed rep amortize the timer read, not the dispatch
    // under test
    let cases: &[(&str, usize, usize)] =
        &[("pool_dispatch fanout4 1k", 4, 1024), ("pool_dispatch fanout8 16k", 8, 16 * 1024)];
    const REPS: usize = 64;
    for &(name, fanout, elems) in cases {
        let init: Vec<u64> = (0..elems as u64).collect();

        // correctness gate before timing anything
        let mut a = init.clone();
        let mut b = init.clone();
        spawn_chunks_mut(&mut a, fanout, step);
        pool::par_chunks_mut(&mut b, fanout, step);
        assert_eq!(a, b, "{name}: pool fan-out diverged from scoped fan-out");

        let mut data = init.clone();
        let spawn = bench(&format!("spawn {name}"), 1, 5, || {
            for _ in 0..REPS {
                spawn_chunks_mut(&mut data, fanout, step);
            }
            std::hint::black_box(&data);
        });
        let c1 = pool::counters();
        let mut data = init.clone();
        let pooled = bench(&format!("pool  {name}"), 2, 10, || {
            for _ in 0..REPS {
                pool::par_chunks_mut(&mut data, fanout, step);
            }
            std::hint::black_box(&data);
        });
        let c2 = pool::counters();
        assert_eq!(
            c2.threads_spawned, c1.threads_spawned,
            "{name}: persistent dispatch spawned OS threads mid-bench"
        );
        let speedup = spawn.median_s / pooled.median_s;
        println!(
            "{}\n{}\n  -> {:.1}us vs {:.1}us per dispatch: {:.2}x (target >= 2x), \
             {} jobs through the persistent queue",
            spawn.row(),
            pooled.row(),
            spawn.median_s / REPS as f64 * 1e6,
            pooled.median_s / REPS as f64 * 1e6,
            speedup,
            c2.jobs_dispatched - c1.jobs_dispatched,
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(name.to_string()));
        o.insert("kind".to_string(), Json::Str("pool_dispatch".to_string()));
        o.insert("fanout".to_string(), json_num(fanout as f64));
        o.insert("elems".to_string(), json_num(elems as f64));
        o.insert("reps".to_string(), json_num(REPS as f64));
        o.insert("spawn_s".to_string(), json_num(spawn.median_s));
        o.insert("pool_s".to_string(), json_num(pooled.median_s));
        o.insert("speedup".to_string(), json_num(speedup));
        o.insert("bit_identical".to_string(), Json::Bool(true));
        cases_json.push(Json::Obj(o));
        report.push(spawn);
        report.push(pooled);
    }
}

/// Naive vs im2col+GEMM integer kernels; asserts bit-identity, reports
/// throughput, and appends its cases to the BENCH_hotpath.json report
/// that `main` writes at the repo root.
fn gemm_benches(
    report: &mut Vec<Stats>,
    cases_json: &mut Vec<Json>,
    top: &mut BTreeMap<String, Json>,
) -> Result<()> {
    println!("--- integer GEMM hot path (naive vs im2col+blocked GEMM) ---");
    let workers = symog::util::pool::default_workers();
    let delta = 0.25f32;
    let mut conv_speedups: Vec<f64> = Vec::new();

    for case in CONV_CASES {
        let mut rng = Rng::new(0x6E3A);
        let (n, h, w) = (case.n, case.h, case.h);
        let k = 3usize;
        let xs: Vec<f32> = (0..n * h * w * case.cin).map(|_| rng.normal()).collect();
        let numel = k * k * case.cin * case.cout;
        let ws = conv_weights(&mut rng, numel, case.n_bits, case.zero_frac, delta);
        let qx = QTensor::from_f32(&xs, [n, h, w, case.cin], 8);
        let qw = QWeight::encode(&ws, [k, k, case.cin, case.cout], delta, case.n_bits);
        let macs = (n * h * w * case.cout * k * k * case.cin) as u64;

        // correctness gate before timing anything
        let mut cg = OpCounts::default();
        let mut cn = OpCounts::default();
        let got = conv2d(&qx, &qw, 1, true, &mut cg);
        let want = conv2d_naive(&qx, &qw, 1, true, &mut cn);
        assert_eq!(got.data, want.data, "{}: GEMM output differs from naive", case.name);
        assert_eq!(cg, cn, "{}: op counts differ", case.name);

        let naive = bench(&format!("naive {}", case.name), 1, 3, || {
            let mut c = OpCounts::default();
            std::hint::black_box(conv2d_naive(&qx, &qw, 1, true, &mut c));
        });
        let gemm = bench(&format!("gemm  {}", case.name), 2, 10, || {
            let mut c = OpCounts::default();
            std::hint::black_box(conv2d(&qx, &qw, 1, true, &mut c));
        });
        let speedup = naive.median_s / gemm.median_s;
        println!(
            "{}\n{}\n  -> {:.1} GMAC/s vs {:.1} GMAC/s: {:.2}x speedup (target >= 3x)",
            naive.row(),
            gemm.row(),
            macs as f64 / naive.median_s / 1e9,
            macs as f64 / gemm.median_s / 1e9,
            speedup,
        );
        conv_speedups.push(speedup);
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(case.name.to_string()));
        o.insert("kind".to_string(), Json::Str("conv2d".to_string()));
        o.insert("batch".to_string(), json_num(n as f64));
        o.insert("macs".to_string(), json_num(macs as f64));
        o.insert("n_bits".to_string(), json_num(case.n_bits as f64));
        o.insert("naive_s".to_string(), json_num(naive.median_s));
        o.insert("gemm_s".to_string(), json_num(gemm.median_s));
        o.insert("speedup".to_string(), json_num(speedup));
        o.insert("bit_identical".to_string(), Json::Bool(true));
        cases_json.push(Json::Obj(o));
        report.push(naive);
        report.push(gemm);
    }

    // dense layer (VGG7 classifier head shape)
    let (dn, fi, fo) = (64usize, 2048usize, 512usize);
    let mut rng = Rng::new(0xD3);
    let xs: Vec<f32> = (0..dn * fi).map(|_| rng.normal()).collect();
    let ws = conv_weights(&mut rng, fi * fo, 2, 0.34, delta);
    let qx = QTensor::from_f32(&xs, [dn, 1, 1, fi], 8);
    let qw = QWeight::encode(&ws, [fi, fo, 1, 1], delta, 2);
    let macs = (dn * fi * fo) as u64;
    let mut cg = OpCounts::default();
    let mut cn = OpCounts::default();
    assert_eq!(dense(&qx, &qw, &mut cg).data, dense_naive(&qx, &qw, &mut cn).data);
    assert_eq!(cg, cn);
    let naive = bench("naive dense 2048->512 b64", 1, 5, || {
        let mut c = OpCounts::default();
        std::hint::black_box(dense_naive(&qx, &qw, &mut c));
    });
    let gemm = bench("gemm  dense 2048->512 b64", 2, 10, || {
        let mut c = OpCounts::default();
        std::hint::black_box(dense(&qx, &qw, &mut c));
    });
    let dense_speedup = naive.median_s / gemm.median_s;
    println!("{}\n{}\n  -> {:.2}x speedup", naive.row(), gemm.row(), dense_speedup);
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str("dense 2048->512".to_string()));
    o.insert("kind".to_string(), Json::Str("dense".to_string()));
    o.insert("batch".to_string(), json_num(dn as f64));
    o.insert("macs".to_string(), json_num(macs as f64));
    o.insert("n_bits".to_string(), json_num(2.0));
    o.insert("naive_s".to_string(), json_num(naive.median_s));
    o.insert("gemm_s".to_string(), json_num(gemm.median_s));
    o.insert("speedup".to_string(), json_num(dense_speedup));
    o.insert("bit_identical".to_string(), Json::Bool(true));
    cases_json.push(Json::Obj(o));
    report.push(naive);
    report.push(gemm);

    // --- interpret vs planned: whole-model forward, VGG7-shaped stack ----
    // Same GEMM kernels on both sides; the delta is everything the plan
    // removed: per-op allocation, per-call im2col scratch, serial epilogue
    // passes (requantize/bias/BN/ReLU now fused + parallel), per-forward
    // retention bookkeeping.
    println!("--- interpret vs planned (VGG7-shaped model forward) ---");
    for (name, n_bits) in [("planned vgg7 b32 w2", 2u32), ("planned vgg7 b32 w8", 8)] {
        let mut rng = Rng::new(0x71A);
        let (man, ck) = models::vgg7ish(&mut rng, n_bits, 32);
        let interp = IntModel::build(&man, &ck)?.with_backend(Backend::Gemm);
        let planned = IntModel::build(&man, &ck)?;
        let batch = 32usize;
        let elems: usize = man.input_shape.iter().product();
        let images: Vec<f32> = (0..batch * elems).map(|_| rng.normal()).collect();

        // correctness gate before timing anything
        let (logits_i, counts_i) = interp.forward(&images, batch)?;
        let (logits_p, counts_p) = planned.forward(&images, batch)?;
        assert_eq!(logits_p, logits_i, "{name}: planned logits differ from interpreted");
        assert_eq!(counts_p, counts_i, "{name}: op counts differ");

        let s_i = bench(&format!("interp {name}"), 1, 6, || {
            std::hint::black_box(interp.forward(&images, batch).unwrap());
        });
        let s_p = bench(&format!("plan   {name}"), 2, 10, || {
            std::hint::black_box(planned.forward(&images, batch).unwrap());
        });
        let speedup = s_i.median_s / s_p.median_s;
        println!(
            "{}\n{}\n  -> {:.2}x planned speedup (target >= 1.2x)",
            s_i.row(),
            s_p.row(),
            speedup,
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(name.to_string()));
        o.insert("kind".to_string(), Json::Str("planned_forward".to_string()));
        o.insert("batch".to_string(), json_num(batch as f64));
        o.insert("n_bits".to_string(), json_num(n_bits as f64));
        o.insert("interp_s".to_string(), json_num(s_i.median_s));
        o.insert("planned_s".to_string(), json_num(s_p.median_s));
        o.insert("speedup".to_string(), json_num(speedup));
        o.insert("bit_identical".to_string(), Json::Bool(true));
        cases_json.push(Json::Obj(o));
        report.push(s_i);
        report.push(s_p);
    }

    train_step_benches(report, cases_json);

    let min = conv_speedups.iter().copied().fold(f64::INFINITY, f64::min);
    let geomean =
        (conv_speedups.iter().map(|s| s.ln()).sum::<f64>() / conv_speedups.len() as f64).exp();
    println!("\nconv speedup: min {min:.2}x, geomean {geomean:.2}x over {workers} workers\n");

    top.insert("conv_speedup_min".to_string(), json_num(min));
    top.insert("conv_speedup_geomean".to_string(), json_num(geomean));
    top.insert("dense_speedup".to_string(), json_num(dense_speedup));
    Ok(())
}

/// One closed-loop serving case: N client threads through `serve::Server`
/// vs the same request corpus as solo batch-1 planned forwards on a
/// single thread.
struct ServeCase {
    name: &'static str,
    model: &'static str,
    clients: usize,
    per_client: usize,
    max_batch: usize,
}

const SERVE_CASES: &[ServeCase] = &[
    // VGG7-shaped: real per-request compute, batching amortizes well
    ServeCase {
        name: "serve vgg7 c4 w2",
        model: "vgg7",
        clients: 4,
        per_client: 24,
        max_batch: 8,
    },
    // LeNet5-shaped: tiny requests, queue/scatter overhead dominates —
    // the stress case for the serving layer itself
    ServeCase {
        name: "serve lenet5 c4 w2",
        model: "lenet5",
        clients: 4,
        per_client: 48,
        max_batch: 8,
    },
];

/// Serving throughput: closed-loop client threads hammering one `Server`
/// vs solo planned forwards of the identical corpus. Bit-identity of every
/// served response against the solo oracle is asserted before timing; the
/// solo/served wall-clock ratio lands in BENCH_hotpath.json as kind
/// `serve_throughput` and is gated by bench_check like the kernel ratios
/// (same-host ratio, so the gate stays machine-invariant).
fn serve_benches(report: &mut Vec<Stats>, cases_json: &mut Vec<Json>) -> Result<()> {
    use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};

    println!("--- serving throughput (closed-loop clients vs solo planned forwards) ---");
    for case in SERVE_CASES {
        let mut rng = Rng::new(0x5E21);
        let (man, ck) = match case.model {
            "vgg7" => models::vgg7ish(&mut rng, 2, 16),
            _ => models::lenet5ish(&mut rng, 2),
        };
        let model = IntModel::build(&man, &ck)?;
        let solo = IntModel::build(&man, &ck)?;
        let elems: usize = man.input_shape.iter().product();
        let total = case.clients * case.per_client;
        let images: Vec<f32> = (0..total * elems).map(|_| rng.normal()).collect();

        let mut reg = Registry::new();
        let opts = RegisterOpts::new().max_batch(case.max_batch);
        let key = reg.add(case.model, ModelSource::InCode(&model), &opts)?;
        // queue_depth = clients: admission control is *active* on the
        // timed path (the hardened checks run per request), but a
        // closed-loop client has at most one request outstanding, so
        // nothing can ever shed — asserted after timing below
        let server = Server::new(reg, ServeConfig::new().queue_depth(case.clients));
        let plan = solo.shared_plan(case.max_batch)?;
        let out_per = plan.out_per_img();

        // correctness gate before timing anything: every served response
        // must equal the solo planned forward of its request
        let mut scratch = plan.scratch_for(1);
        let solos: Vec<Vec<f32>> = (0..total)
            .map(|r| plan.run(&images[r * elems..(r + 1) * elems], 1, &mut scratch))
            .collect::<Result<_>>()?;
        std::thread::scope(|sc| {
            for t in 0..case.clients {
                let (server, key, images, solos) = (&server, &key, &images, &solos);
                sc.spawn(move || {
                    for i in 0..case.per_client {
                        let r = t * case.per_client + i;
                        let got = server
                            .infer(key, &images[r * elems..(r + 1) * elems])
                            .expect("serve request failed");
                        assert_eq!(
                            got, solos[r],
                            "{}: request {r} diverged from solo forward",
                            case.name
                        );
                    }
                });
            }
        });

        let mut row_out = vec![0f32; out_per];
        let s_solo = bench(&format!("solo  {}", case.name), 1, 5, || {
            for r in 0..total {
                plan.run_into(
                    &images[r * elems..(r + 1) * elems],
                    1,
                    &mut scratch,
                    &mut row_out,
                )
                .unwrap();
                std::hint::black_box(&row_out);
            }
        });
        let mut hammer = || {
            std::thread::scope(|sc| {
                for t in 0..case.clients {
                    let (server, key, images) = (&server, &key, &images);
                    sc.spawn(move || {
                        for i in 0..case.per_client {
                            let r = t * case.per_client + i;
                            let got = server
                                .infer(key, &images[r * elems..(r + 1) * elems])
                                .expect("serve request failed");
                            std::hint::black_box(got);
                        }
                    });
                }
            });
        };
        // warm up outside bench() so the stats delta below covers exactly
        // the timed reps (the correctness gate above has a different
        // per-request cost profile and would dilute the occupancy number)
        hammer();
        let pre = server.stats(&key)?;
        let s_serve = bench(&format!("serve {}", case.name), 0, 5, &mut hammer);
        let post = server.stats(&key)?;
        // the failure-domain layer must be invisible to healthy traffic:
        // same floors as before the hardening (gated by bench_check), and
        // zero refusals — every timed request was served, none shed,
        // swept, or failed
        anyhow::ensure!(
            (post.sheds, post.timeouts, post.failures) == (0, 0, 0),
            "{}: hardened serve path refused healthy closed-loop traffic \
             ({} shed, {} timed out, {} failed)",
            case.name,
            post.sheds,
            post.timeouts,
            post.failures
        );
        let timed_occ = (post.requests - pre.requests) as f64
            / (post.batches - pre.batches).max(1) as f64;
        let speedup = s_solo.median_s / s_serve.median_s;
        println!(
            "{}\n{}\n  -> {:.2}x served-vs-solo ({:.0} req/s served, \
             mean occupancy {:.2} over the timed reps)",
            s_solo.row(),
            s_serve.row(),
            speedup,
            total as f64 / s_serve.median_s,
            timed_occ,
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(case.name.to_string()));
        o.insert("kind".to_string(), Json::Str("serve_throughput".to_string()));
        o.insert("clients".to_string(), json_num(case.clients as f64));
        o.insert("requests".to_string(), json_num(total as f64));
        o.insert("max_batch".to_string(), json_num(case.max_batch as f64));
        o.insert("n_bits".to_string(), json_num(2.0));
        o.insert("solo_s".to_string(), json_num(s_solo.median_s));
        o.insert("serve_s".to_string(), json_num(s_serve.median_s));
        o.insert("speedup".to_string(), json_num(speedup));
        o.insert("bit_identical".to_string(), Json::Bool(true));
        o.insert("mean_occupancy".to_string(), json_num(timed_occ));
        cases_json.push(Json::Obj(o));
        report.push(s_solo);
        report.push(s_serve);
    }
    Ok(())
}

/// One f32 training-step comparison case (stride-1 SAME conv, VGG7-shaped).
struct TrainCase {
    name: &'static str,
    batch: usize,
    h: usize,
    cin: usize,
    cout: usize,
}

const TRAIN_CASES: &[TrainCase] = &[
    // VGG7 mid-stack shape
    TrainCase { name: "train conv3 16x16 64->64 b8", batch: 8, h: 16, cin: 64, cout: 64 },
    // VGG7 top-stack shape
    TrainCase { name: "train conv5 8x8 128->128 b8", batch: 8, h: 8, cin: 128, cout: 128 },
];

/// Native-training hot path: sequential naive conv fwd+bwd vs the shared
/// packed-panel GEMM path (im2col GEMM forward; dy·Wᵀ + col2im and
/// patchesᵀ·dy backward, batch-parallel with the deterministic cell
/// reduction). Gradient agreement is asserted before timing; the speedup
/// ratio feeds the `train_step` bench_check floor cases.
fn train_step_benches(report: &mut Vec<Stats>, cases_json: &mut Vec<Json>) {
    println!("--- native training hot path (naive loops vs shared GEMM core) ---");
    for case in TRAIN_CASES {
        let mut rng = Rng::new(0x7261);
        let s = tops::Conv2dShape {
            h: case.h,
            w: case.h,
            cin: case.cin,
            k: 3,
            stride: 1,
            cout: case.cout,
        };
        let batch = case.batch;
        // post-ReLU-shaped activations: exact zeros exercise both skips
        let x: Vec<f32> = (0..s.in_elems(batch))
            .map(|_| if rng.bool(0.4) { 0.0 } else { rng.normal() })
            .collect();
        let w: Vec<f32> = (0..s.weight_elems()).map(|_| rng.normal() * 0.1).collect();
        let b: Vec<f32> = (0..s.cout).map(|_| rng.normal() * 0.1).collect();
        let dy: Vec<f32> = (0..s.out_elems(batch)).map(|_| rng.normal() * 0.1).collect();
        let macs = (s.out_elems(batch) * s.k * s.k * s.cin) as u64 * 3; // fwd + dx + dw

        // correctness gate before timing anything (coarse here — the
        // 2048-term reductions amplify f32 ordering noise; the tight
        // epsilon races live in the train::ops property tests)
        let yg = tops::conv2d_forward(&x, &w, &b, batch, &s);
        let yn = tops::conv2d_forward_naive(&x, &w, &b, batch, &s);
        symog::testing::assert_allclose_rel(&yg, &yn, 1e-3, 1e-3);
        let (dxg, dwg, dbg) = tops::conv2d_backward(&x, &w, &dy, batch, &s);
        let (dxn, dwn, dbn) = tops::conv2d_backward_naive(&x, &w, &dy, batch, &s);
        symog::testing::assert_allclose_rel(&dxg, &dxn, 1e-3, 1e-3);
        symog::testing::assert_allclose_rel(&dwg, &dwn, 1e-3, 1e-3);
        symog::testing::assert_allclose_rel(&dbg, &dbn, 1e-3, 1e-3);

        let naive = bench(&format!("naive {}", case.name), 1, 3, || {
            std::hint::black_box(tops::conv2d_forward_naive(&x, &w, &b, batch, &s));
            std::hint::black_box(tops::conv2d_backward_naive(&x, &w, &dy, batch, &s));
        });
        let gemm = bench(&format!("gemm  {}", case.name), 1, 6, || {
            std::hint::black_box(tops::conv2d_forward(&x, &w, &b, batch, &s));
            std::hint::black_box(tops::conv2d_backward(&x, &w, &dy, batch, &s));
        });
        let speedup = naive.median_s / gemm.median_s;
        println!(
            "{}\n{}\n  -> {:.1} GMAC/s vs {:.1} GMAC/s: {:.2}x speedup (target >= 3x)",
            naive.row(),
            gemm.row(),
            macs as f64 / naive.median_s / 1e9,
            macs as f64 / gemm.median_s / 1e9,
            speedup,
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(case.name.to_string()));
        o.insert("kind".to_string(), Json::Str("train_step".to_string()));
        o.insert("batch".to_string(), json_num(batch as f64));
        o.insert("macs".to_string(), json_num(macs as f64));
        o.insert("naive_s".to_string(), json_num(naive.median_s));
        o.insert("gemm_s".to_string(), json_num(gemm.median_s));
        o.insert("speedup".to_string(), json_num(speedup));
        cases_json.push(Json::Obj(o));
        report.push(naive);
        report.push(gemm);
    }
}

fn substrate_benches(report: &mut Vec<Stats>) {
    println!("--- substrates ---");
    let mut rng = Rng::new(0);
    let w: Vec<f32> = (0..1_000_000).map(|_| rng.normal() * 0.3).collect();
    let mut out = vec![0f32; w.len()];

    let s = bench("quantize_slice 1M f32", 2, 20, || {
        fixedpoint::quantize_slice(&w, 0.25, 2, &mut out);
        std::hint::black_box(&out);
    });
    println!("{}  ({:.0} Melem/s)", s.row(), s.throughput(w.len()) / 1e6);
    report.push(s);

    let s = bench("optimal_delta_refined 1M f32", 1, 10, || {
        std::hint::black_box(fixedpoint::optimal_delta_refined(&w, 2));
    });
    println!("{}", s.row());
    report.push(s);

    let s = bench("mode_indices 1M f32", 2, 20, || {
        std::hint::black_box(fixedpoint::mode_indices(&w, 0.25, 2));
    });
    println!("{}", s.row());
    report.push(s);

    let mut tracker = ModeTracker::new(1, 2);
    tracker.record([(w.as_slice(), 0.25f32)].into_iter());
    let s = bench("tracker.record 1M weights", 1, 10, || {
        std::hint::black_box(tracker.record([(w.as_slice(), 0.25f32)].into_iter()));
    });
    println!("{}", s.row());
    report.push(s);

    let s = bench("synth-cifar10 generate 1k imgs", 1, 5, || {
        std::hint::black_box(symog::data::synth_dataset(
            &Preset::SynthCifar10.spec(),
            1000,
            1,
        ));
    });
    println!("{}", s.row());
    report.push(s);

    let (train, _) = Preset::SynthCifar10.load(2048, 64, 0);
    let mut images = Vec::new();
    let mut labels = Vec::new();
    let s = bench("batch assembly 64x32x32x3 (augmented)", 5, 50, || {
        let mut it = BatchIter::new(&train, 64, 1, 0, AugmentConfig::cifar());
        it.next_into(&mut images, &mut labels);
        std::hint::black_box(&images);
    });
    println!("{}", s.row());
    report.push(s);
}

fn runtime_benches(report: &mut Vec<Stats>, engine_only: bool) -> Result<()> {
    println!("\n--- runtime hot path (lenet5 symog artifact) ---");
    let rt = Runtime::cpu()?;
    let tag = std::env::var("SYMOG_HOTPATH_TAG")
        .unwrap_or_else(|_| "lenet5-symog-synth-mnist-w1-b2".to_string());
    let dir = artifacts_root().join(&tag);
    println!("artifact: {tag}");
    let art = rt.load_artifact(&dir)?;
    let man = &art.manifest;
    let batch = man.batch;
    let (train, test) = Preset::SynthMnist.load(2048, 512, 0);
    let mut trainer = Trainer::from_init(&art)?;
    if engine_only {
        let ck = trainer.to_checkpoint()?;
        let model = IntModel::build(man, &ck)?;
        let s = bench_budgeted("integer engine 64 imgs", 1, 15.0, 50, || {
            std::hint::black_box(
                model
                    .accuracy(&test.images[..64 * test.image_elems()], &test.labels[..64], 64)
                    .unwrap(),
            );
        });
        println!("{}  ({:.0} imgs/s)", s.row(), s.throughput(64));
        report.push(s);
        return Ok(());
    }

    // batch assembly alone
    let mut images = Vec::new();
    let mut labels = Vec::new();
    let mut it = BatchIter::new(&train, batch, 1, 0, AugmentConfig::none());
    it.next_into(&mut images, &mut labels);
    let img_dims = [batch, man.input_shape[0], man.input_shape[1], man.input_shape[2]];

    let s = bench("literal upload (images+labels)", 5, 100, || {
        std::hint::black_box(literal_f32(&images, &img_dims).unwrap());
        std::hint::black_box(literal_i32(&labels, &[batch]).unwrap());
    });
    println!("{}", s.row());
    report.push(s.clone());
    let upload = s.median_s;

    // full train step through the coordinator (includes upload + download)
    let s = bench_budgeted("train step end-to-end (batch 64)", 3, 10.0, 200, || {
        let mut opts = symog::coordinator::TrainOptions::paper(1);
        opts.steps_per_epoch = Some(1);
        opts.seed = 1;
        trainer.run_epoch(&train, &opts, 0.01, 1.0).unwrap();
    });
    println!("{}  ({:.1} imgs/s)", s.row(), s.throughput(batch));
    let step = s.median_s;
    report.push(s);

    // execute-only: pre-built literals, direct run()
    let deltas_lit = literal_f32(trainer.deltas(), &[man.deltas_len()])?;
    let img_lit = literal_f32(&images, &img_dims)?;
    let lab_lit = literal_i32(&labels, &[batch])?;
    let lr_lit = literal_scalar_f32(0.01);
    let lam_lit = literal_scalar_f32(1.0);
    // stable state snapshot for pure-execute timing
    let ck = trainer.to_checkpoint()?;
    let t2 = Trainer::from_checkpoint(&art, &ck, false)?;
    let params: Vec<xla::Literal> = (0..man.params.len())
        .map(|i| literal_f32(&t2.backend.param_host(i).unwrap(), &man.params[i].shape).unwrap())
        .collect();
    let zeros: Vec<xla::Literal> = man
        .params
        .iter()
        .map(|p| literal_f32(&vec![0.0; p.numel()], &p.shape).unwrap())
        .collect();
    let state: Vec<xla::Literal> = man
        .state
        .iter()
        .map(|st| {
            let t = ck.find(&st.name).unwrap();
            literal_f32(&t.data, &st.shape).unwrap()
        })
        .collect();
    let s = bench_budgeted("execute only (train exe)", 3, 10.0, 200, || {
        let mut args: Vec<&xla::Literal> = vec![&img_lit, &lab_lit];
        args.extend(params.iter());
        args.extend(zeros.iter());
        args.extend(state.iter());
        args.push(&deltas_lit);
        args.push(&lr_lit);
        args.push(&lam_lit);
        std::hint::black_box(run(&art.train, &args).unwrap());
    });
    println!("{}", s.row());
    let exec = s.median_s;
    report.push(s);
    println!(
        "coordinator overhead: step {} vs execute {} -> {:.1}% outside execute (target <10%)",
        fmt_time(step),
        fmt_time(exec),
        (step - exec) / step * 100.0,
    );
    println!("(upload share: {:.1}%)", upload / step * 100.0);

    // eval throughput
    let s = bench_budgeted("evalq full test set (512 imgs)", 1, 15.0, 50, || {
        std::hint::black_box(trainer.evaluate(&test, true).unwrap());
    });
    println!("{}  ({:.0} imgs/s)", s.row(), s.throughput(test.len()));
    report.push(s);

    // integer engine throughput
    let model = IntModel::build(man, &ck)?;
    let s = bench_budgeted("integer engine 64 imgs", 1, 15.0, 50, || {
        std::hint::black_box(
            model
                .accuracy(&test.images[..64 * test.image_elems()], &test.labels[..64], 64)
                .unwrap(),
        );
    });
    println!("{}  ({:.0} imgs/s)", s.row(), s.throughput(64));
    report.push(s);
    Ok(())
}
