//! Host-side stand-in for the `xla` PJRT bindings.
//!
//! The real crate wraps XLA's PJRT C API; that native runtime is not
//! vendored in this environment, so this crate keeps the same surface the
//! coordinator compiles against:
//!
//! * [`Literal`] is fully functional host-side (typed buffer + dims +
//!   tuples) — everything that only moves tensors between Rust vectors and
//!   literals works for real, including the unit tests around it;
//! * [`PjRtClient`] comes up as a stub "host" platform, and
//!   [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`] return a
//!   clear error instead of running HLO, so every artifact-driven path
//!   degrades to the same "artifact unavailable" skip the repo already
//!   handles when `make artifacts` has not been run.
//!
//! Swapping in the real bindings is a one-line Cargo.toml change; no call
//! site needs to move.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring the binding crate's: a plain message.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "XLA/PJRT native runtime is not available in this build \
                        (stub xla crate); compiled-artifact paths are disabled";

// ---------------------------------------------------------------------------
// literals

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<&[Self]>;
}

/// Backing storage of a literal (public only for the `NativeType` plumbing).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::F32(data)
    }

    fn unwrap(data: &Data) -> Option<&[Self]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Data {
        Data::I32(data)
    }

    fn unwrap(data: &Data) -> Option<&[Self]> {
        match data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host tensor: typed buffer + dimensions, or a tuple of literals.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    fn numel(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret the buffer under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.numel() {
            return Err(Error::new(format!(
                "reshape to {dims:?} ({n} elems) from {} elems",
                self.numel()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Download to a host vector (type must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error::new("literal element type mismatch"))
    }

    /// Flatten a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(v) => Ok(v.clone()),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }

    /// The array shape (errors on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Generic shape (dims only in this stub).
    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape { dims: self.dims.clone() })
    }
}

/// Shape of a non-tuple literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of any literal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape {
    pub dims: Vec<i64>,
}

// ---------------------------------------------------------------------------
// client / executables (stubbed)

/// Parsed HLO module (held as text in this stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Read HLO text from a file. Fails if the file is unreadable — the one
    /// behavior artifact-discovery code observably depends on.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// PJRT client. The stub "host" platform exists (so the process can probe
/// for it), but compilation is unavailable.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "stub-host" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }
}

/// A compiled executable. Unconstructible through the stub client (compile
/// always errors), but the type keeps every call site well-formed.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3]).is_err());
    }

    #[test]
    fn literal_type_checked() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn scalar_is_rank0() {
        let l = Literal::scalar(2.5f32);
        assert!(l.array_shape().unwrap().dims().is_empty());
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn client_up_compile_stubbed() {
        let c = PjRtClient::cpu().unwrap();
        assert!(!c.platform_name().is_empty());
        let proto = HloModuleProto { text: String::new() };
        assert!(c.compile(&XlaComputation::from_proto(&proto)).is_err());
    }

    #[test]
    fn missing_hlo_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
