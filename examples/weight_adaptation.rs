//! Section 4.4's self-reliant weight adaptation study (Figures 3 and 4),
//! running entirely on the pure-Rust native training backend — no AOT
//! artifact, no Python, no PJRT:
//!
//!     cargo run --release --example weight_adaptation [-- --fast]
//!
//! A small convnet trains on synthetic CIFAR-100 twice — with and without
//! weight clipping (section 3.4) — and prints per-epoch mode-switch rates
//! (Fig. 4) plus the evolving layer-0 weight histograms (Fig. 3).

use anyhow::Result;
use symog::coordinator::{TrainOutcome, Trainer, TrainOptions};
use symog::data::{AugmentConfig, Preset};
use symog::train::{NativeBackend, NativeHyper, NativeModel};

fn run(
    clip: bool,
    epochs: u32,
    train: &symog::data::Dataset,
    test: &symog::data::Dataset,
    steps: Option<usize>,
) -> Result<TrainOutcome> {
    let model = NativeModel::convnet([32, 32, 3], &[16, 32], 100, 0);
    let hyper = NativeHyper { clip, ..NativeHyper::default() };
    let mut trainer = Trainer::new(NativeBackend::new(model, hyper, 32));
    let mut opts = TrainOptions::paper(epochs);
    opts.seed = 1;
    opts.augment = AugmentConfig::cifar(); // the paper's CIFAR protocol
    opts.steps_per_epoch = steps;
    opts.track_modes = true;
    opts.hist_epochs = vec![0, epochs / 2, epochs];
    opts.hist_layers = vec![0];
    opts.verbose = true;
    trainer.train(train, test, &opts)
}

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (epochs, train_n, test_n, steps) = if fast {
        (4u32, 512usize, 128usize, Some(8usize))
    } else {
        (12, 2048, 512, None)
    };
    let (train, test) = Preset::SynthCifar100.load(train_n, test_n, 0);
    println!(
        "native backend — synth-cifar100, {} train / {} test, {} epochs{}",
        train.len(),
        test.len(),
        epochs,
        if fast { " (--fast)" } else { "" }
    );

    let mut results = Vec::new();
    for (label, clip) in [("with clipping", true), ("without clipping", false)] {
        println!("\n=== SYMOG {label} ===");
        results.push((label, run(clip, epochs, &train, &test, steps)?));
    }

    println!("\nmode-switch rate per epoch, mean over layers (Figure 4):");
    println!("{:>6} | {:>14} | {:>16}", "epoch", "with clip", "without clip");
    let (with, without) = (&results[0].1, &results[1].1);
    for (i, (a, b)) in with.log.epochs.iter().zip(&without.log.epochs).enumerate() {
        println!(
            "{:>6} | {:>13.1}% | {:>15.1}%",
            i + 1,
            a.switch_rate * 100.0,
            b.switch_rate * 100.0
        );
    }

    println!("\nlayer-0 weight histograms over training (Figure 3, with clip):");
    let hists = &with.histograms[0].1;
    for (e, h) in hists.epochs.iter().zip(&hists.hists) {
        println!("  epoch {e:2}  {}", h.sparkline());
    }

    println!(
        "\nfinal quantized error: with clip {:.2}%  without clip {:.2}%",
        with.log.best_quantized_error() * 100.0,
        without.log.best_quantized_error() * 100.0
    );
    std::fs::create_dir_all("results").ok();
    if let Some(t) = &with.tracker {
        std::fs::write("results/fig4_with_clip.csv", t.to_csv())?;
    }
    if let Some(t) = &without.tracker {
        std::fs::write("results/fig4_without_clip.csv", t.to_csv())?;
    }
    println!("switch-rate CSVs -> results/fig4_*.csv");
    Ok(())
}
