//! Section 4.4's self-reliant weight adaptation study (Figures 3 and 4):
//! train VGG11 (width-scaled) on synthetic CIFAR-100 twice — with and
//! without weight clipping — and print the per-layer mode-switch rates and
//! the evolving weight histograms.
//!
//!     make artifacts && cargo run --release --example weight_adaptation

use anyhow::Result;
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (epochs, train_n, test_n, steps) = if fast {
        (4u32, 1024usize, 256usize, Some(8usize))
    } else {
        (16, 4096, 512, None)
    };
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let base = Experiment {
        name: "weight-adaptation".into(),
        artifact: String::new(),
        dataset: Preset::SynthCifar100,
        train_n,
        test_n,
        epochs,
        augment: true,
        steps_per_epoch: steps,
        track_modes: true,
        hist_epochs: vec![0, epochs / 2, epochs],
        hist_layers: vec![0, 3, 6], // the paper plots layers 1, 4, 7 (1-based)
        verbose: true,
        ..Default::default()
    };

    let (train, test) = Preset::SynthCifar100.load(train_n, test_n, 0);
    let mut results = Vec::new();
    for (label, artifact) in [
        ("with clipping", "vgg11-symog-synth-cifar100-w0.25-b2"),
        ("without clipping", "vgg11-symog-synth-cifar100-w0.25-b2-noclip"),
    ] {
        println!("=== SYMOG {label} ===");
        let exp = Experiment { artifact: artifact.into(), ..base.clone() };
        let art = driver::load_artifact(&rt, &exp, &root)?;
        let result = driver::run_experiment(&art, &exp, &train, &test)?;
        results.push((label, result));
        println!();
    }

    println!("mode-switch rate per epoch, mean over layers (Figure 4):");
    println!("{:>6} | {:>14} | {:>16}", "epoch", "with clip", "without clip");
    let (with, without) = (&results[0].1, &results[1].1);
    for (i, (a, b)) in with
        .outcome
        .log
        .epochs
        .iter()
        .zip(&without.outcome.log.epochs)
        .enumerate()
    {
        println!(
            "{:>6} | {:>13.1}% | {:>15.1}%",
            i + 1,
            a.switch_rate * 100.0,
            b.switch_rate * 100.0
        );
    }

    println!("\nlayer-1 weight histograms over training (Figure 3, with clip):");
    let hists = &with.outcome.histograms[0].1;
    for (e, h) in hists.epochs.iter().zip(&hists.hists) {
        println!("  epoch {e:2}  {}", h.sparkline());
    }

    println!(
        "\nfinal quantized error: with clip {:.2}%  without clip {:.2}%",
        with.best_q_error * 100.0,
        without.best_q_error * 100.0
    );
    std::fs::create_dir_all("results").ok();
    if let Some(t) = &with.outcome.tracker {
        std::fs::write("results/fig4_with_clip.csv", t.to_csv())?;
    }
    if let Some(t) = &without.outcome.tracker {
        std::fs::write("results/fig4_without_clip.csv", t.to_csv())?;
    }
    println!("switch-rate CSVs -> results/fig4_*.csv");
    Ok(())
}
