//! The `.fxpa` serving-artifact lifecycle, end to end:
//!
//! 1. **publish** a quantized model to a versioned on-disk artifact
//!    (packed mantissas + per-layer deltas + integrity checksum);
//! 2. **load** it back — straight to a compiled plan, no re-quantization —
//!    and verify bit-identity against the in-code model;
//! 3. **register** the artifact as a model source and serve it;
//! 4. **hot-swap** a newer version in under traffic and watch per-version
//!    stats partition the requests.
//!
//!     cargo run --release --example publish_artifact -- \
//!         --model lenet5 --bits 4 --requests 12 --seed 1453
//!
//! By default the artifact is written under the system temp dir and
//! removed at exit; pass `--out some/model.fxpa` to keep it (CI uploads
//! one this way).

use anyhow::{bail, ensure, Result};
use symog::artifact::{self, PublishOpts};
use symog::cli::Args;
use symog::inference::IntModel;
use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let model_name = args.str_or("model", "lenet5");
    let bits = args.usize_or("bits", 4)? as u32;
    let requests = args.usize_or("requests", 12)?.max(2);
    let seed = args.u64_or("seed", 0x1453)?;
    let out = args.str_or("out", "");
    args.finish()?;

    let mut rng = Rng::new(seed);
    let gen = |rng: &mut Rng| match model_name.as_str() {
        "lenet5" => Ok(models::lenet5ish(rng, bits)),
        "vgg7" => Ok(models::vgg7ish(rng, bits, 8)),
        "densenet" => Ok(models::densenetish(rng, bits)),
        other => bail!("unknown --model {other:?} (lenet5|vgg7|densenet)"),
    };
    let (man, ck) = gen(&mut rng)?;
    let elems: usize = man.input_shape.iter().product();

    // 1. publish --------------------------------------------------------
    let keep = !out.is_empty();
    let path = if keep {
        let p = std::path::PathBuf::from(&out);
        if let Some(parent) = p.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)?;
        }
        p
    } else {
        std::env::temp_dir().join(format!("symog-example-{}.fxpa", std::process::id()))
    };
    let info = artifact::publish(&man, &ck, &PublishOpts::new().version(1), &path)?;
    println!(
        "published {} -> {}  (v{}, {} quant + {} aux tensors, {} bytes)",
        man.model,
        path.display(),
        info.version,
        info.quant_tensors,
        info.aux_tensors,
        info.bytes
    );
    println!("peek_version (header-only read): v{}", artifact::peek_version(&path)?);

    // 2. load + bit-identity check --------------------------------------
    let solo = IntModel::build(&man, &ck)?;
    let loaded = artifact::load(&path)?;
    let img: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
    let (want, _) = solo.forward(&img, 1)?;
    let (got, _) = loaded.model.forward(&img, 1)?;
    ensure!(got == want, "loaded artifact diverged from the in-code model");
    println!("load: logits bit-identical to the in-code model ({} values)", got.len());

    // 3. serve from the artifact ----------------------------------------
    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(4);
    let key = reg.add(&model_name, ModelSource::Artifact(&path), &opts)?;
    let server = Server::new(reg, ServeConfig::new().workers(2));
    println!("serving {key} from the artifact");
    for r in 0..requests / 2 {
        let img: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
        let (logits, v) = server.infer_versioned(&key, &img)?;
        ensure!(v == 1, "expected version 1 to serve request {r}");
        std::hint::black_box(logits);
    }

    // 4. hot-swap v2 in (same architecture, fresh weights) --------------
    let (man2, ck2) = gen(&mut rng)?;
    let next = IntModel::build(&man2, &ck2)?;
    let k2 = server.swap(&key, ModelSource::InCode(&next), &opts)?;
    println!("hot-swapped {k2} in (traffic never paused)");
    for r in 0..requests - requests / 2 {
        let img: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
        let (logits, v) = server.infer_versioned(&key, &img)?;
        ensure!(v == 2, "expected version 2 to serve request {r} after the swap");
        std::hint::black_box(logits);
    }

    for (v, stats) in server.stats_by_version(&key)? {
        println!("v{v}: {}", stats.render());
    }
    let total = server.stats(&key)?;
    ensure!(total.requests == requests as u64, "stats lost a request");
    println!("total: {}", total.render());

    if keep {
        println!("kept artifact at {}", path.display());
    } else {
        std::fs::remove_file(&path)?;
    }
    Ok(())
}
