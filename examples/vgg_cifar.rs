//! Section 4.2's CIFAR-10 experiment: VGG7 (width-scaled) with SYMOG vs the
//! TWN comparator and the float baseline — the three-way comparison that
//! anchors the paper's Table 1 CIFAR-10 block.
//!
//!     make artifacts && cargo run --release --example vgg_cifar
//!
//! Pass `--fast` for a shortened run.

use anyhow::Result;
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::inference::IntModel;
use symog::report::{render_table1, Table1Row};
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (epochs, train_n, test_n, steps) = if fast {
        (3u32, 1024usize, 256usize, Some(8usize))
    } else {
        (15, 4096, 512, None)
    };

    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let base = Experiment {
        name: "vgg7-cifar".into(),
        artifact: String::new(),
        dataset: Preset::SynthCifar10,
        train_n,
        test_n,
        epochs,
        augment: true,
        steps_per_epoch: steps,
        verbose: true,
        ..Default::default()
    };

    let (train, test) = Preset::SynthCifar10.load(train_n, test_n, 0);
    let mut rows = Vec::new();
    for (label, artifact, lambda_kind, bits, fixed) in [
        ("SYMOG", "vgg7-symog-synth-cifar10-w0.25-b2", "exp", "2", true),
        ("TWN", "vgg7-twn-synth-cifar10-w0.25-b2", "off", "2", false),
        ("Baseline", "vgg7-baseline-synth-cifar10-w0.25-b2", "off", "32", false),
    ] {
        println!("=== {label} ===");
        let exp = Experiment {
            artifact: artifact.into(),
            lambda_kind: lambda_kind.into(),
            ..base.clone()
        };
        let art = driver::load_artifact(&rt, &exp, &root)?;
        let result = driver::run_experiment(&art, &exp, &train, &test)?;
        let err = if bits == "32" { result.best_f_error } else { result.best_q_error };
        println!("{label}: best error {:.2}%", err * 100.0);
        if label == "SYMOG" {
            // serve the hard-quantized VGG7 through the planned integer
            // engine: one compiled ExecPlan, reused across every batch
            let model = IntModel::build(&art.manifest, &result.final_ckpt)?;
            let plan = model.shared_plan(64)?;
            let t0 = std::time::Instant::now();
            let acc = model.accuracy(&test.images, &test.labels, 64)?;
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "planned integer inference: acc {:.4}, {:.0} imgs/s \
                 ({} fused steps, {} KiB arena); energy ratio {:.1}x (analytic)",
                acc,
                test.len() as f64 / dt.max(1e-9),
                plan.num_steps(),
                plan.arena_bytes() / 1024,
                model.cost_report(1)?.energy_ratio()
            );
        }
        println!();
        rows.push(Table1Row {
            dataset: "synth-cifar10".into(),
            method: label.into(),
            model: "VGG7 (0.25x)".into(),
            params: art.manifest.num_params(),
            bits: bits.into(),
            fixed_point: fixed,
            epochs,
            error: err,
        });
    }
    println!("{}", render_table1(&rows));
    Ok(())
}
