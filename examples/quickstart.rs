//! Quickstart: train a small MLP with SYMOG on synthetic MNIST in under a
//! minute, watch the weight distribution turn trimodal, and evaluate the
//! hard-quantized model.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the smallest end-to-end path through the whole stack: the Rust
//! coordinator drives an AOT-compiled JAX/Pallas train step via PJRT.

use anyhow::{Context, Result};
use symog::config::Experiment;
use symog::coordinator::mode_occupancy;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let exp = Experiment {
        name: "quickstart".into(),
        artifact: "mlp-symog-synth-mnist-w1-b2".into(),
        dataset: Preset::SynthMnist,
        train_n: 4096,
        test_n: 512,
        epochs: 8,
        track_modes: true,
        hist_epochs: vec![0, 8],
        hist_layers: vec![0],
        ..Default::default()
    };
    let artifact = driver::load_artifact(&rt, &exp, &artifacts_root())
        .context("run `make artifacts` first")?;
    println!(
        "SYMOG quickstart — {} on {}, {} params, N={} bits",
        artifact.manifest.model,
        exp.dataset.name(),
        symog::report::human_count(artifact.manifest.num_params()),
        artifact.manifest.n_bits,
    );

    let (train, test) = exp.dataset.load(exp.train_n, exp.test_n, exp.seed);
    let result = driver::run_experiment(&artifact, &exp, &train, &test)?;

    // weight distribution before/after (paper Figure 1, in sparklines)
    let hists = &result.outcome.histograms[0].1;
    println!("\nlayer-0 weight distribution (Figure 1):");
    for (e, h) in hists.epochs.iter().zip(&hists.hists) {
        println!("  epoch {e:2}  {}", h.sparkline());
    }

    // final mode occupancy: three Gaussian modes collapsed onto the codebook
    let deltas = &result.final_ckpt.find("__deltas__").unwrap().data;
    let w0 = &result
        .final_ckpt
        .tensors
        .iter()
        .find(|t| t.kind == symog::coordinator::Kind::Weight)
        .unwrap();
    let occ = mode_occupancy(&w0.data, deltas[0], 2);
    println!("\nlayer-0 mode occupancy {{-Δ, 0, +Δ}}: {occ:?}");

    let last = result.outcome.log.last().unwrap();
    println!(
        "\nfinal: float acc {:.3} | quantized acc {:.3} | best quantized error {:.2}%",
        last.test_acc,
        last.testq_acc,
        result.best_q_error * 100.0
    );
    Ok(())
}
