//! End-to-end driver (DESIGN.md §End-to-end validation): the paper's
//! section-4.1 experiment — pretrain LeNet-5 in float, then SYMOG-train it
//! to 2-bit fixed point — with the loss curve, epoch metrics, and the final
//! Table-1-style row logged to results/.
//!
//!     make artifacts && cargo run --release --example lenet_mnist
//!
//! Pass `--fast` for a shortened run (CI smoke).

use std::path::Path;

use anyhow::{Context, Result};
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::inference::IntModel;
use symog::report::{render_table1, Table1Row};
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    let (epochs_base, epochs_symog, train_n, test_n) =
        if fast { (2, 3, 1024, 256) } else { (10, 25, 8192, 1024) };

    let baseline = Experiment {
        name: "lenet-baseline".into(),
        artifact: "lenet5-baseline-synth-mnist-w1-b2".into(),
        dataset: Preset::SynthMnist,
        train_n,
        test_n,
        epochs: epochs_base,
        lambda_kind: "off".into(),
        verbose: true,
        ..Default::default()
    };
    let symog_exp = Experiment {
        name: "lenet-symog".into(),
        artifact: "lenet5-symog-synth-mnist-w1-b2".into(),
        epochs: epochs_symog,
        track_modes: true,
        hist_epochs: vec![0, epochs_symog / 2, epochs_symog],
        hist_layers: vec![0, 2, 4],
        ..baseline.clone()
    };

    let (train, test) = Preset::SynthMnist.load(train_n, test_n, 0);
    println!(
        "=== phase 1: FP32 pretraining ({epochs_base} epochs), then phase 2: \
         SYMOG 2-bit training ({epochs_symog} epochs) ==="
    );
    let (base, symog_run) =
        driver::pretrain_then_run(&rt, &baseline, &symog_exp, &root, &train, &test)?;

    // loss curve (the end-to-end validation record for EXPERIMENTS.md)
    println!("\nSYMOG loss curve:");
    for e in &symog_run.outcome.log.epochs {
        println!(
            "  epoch {:3}  train_loss {:.4}  testq_err {:.2}%  switch {:.1}%",
            e.epoch,
            e.train_loss,
            e.quantized_error() * 100.0,
            e.switch_rate * 100.0
        );
    }

    let params = 62_582; // LeNet-5 at width 1.0
    let rows = vec![
        Table1Row {
            dataset: "synth-mnist".into(),
            method: "SYMOG".into(),
            model: "LeNet5".into(),
            params,
            bits: "2".into(),
            fixed_point: true,
            epochs: epochs_symog,
            error: symog_run.best_q_error,
        },
        Table1Row {
            dataset: "synth-mnist".into(),
            method: "Baseline".into(),
            model: "LeNet5".into(),
            params,
            bits: "32".into(),
            fixed_point: false,
            epochs: epochs_base,
            error: base.best_f_error,
        },
    ];
    println!("\n{}", render_table1(&rows));

    // deploy check: the trained 2-bit model through the planned integer
    // engine (compiled ExecPlan, arena-backed, analytic cost report)
    let art = driver::load_artifact(&rt, &symog_exp, &root)?;
    let model = IntModel::build(&art.manifest, &symog_run.final_ckpt)?;
    let plan = model.shared_plan(64)?;
    let t0 = std::time::Instant::now();
    let acc_int = model.accuracy(&test.images, &test.labels, 64)?;
    println!(
        "planned integer inference: acc {:.4} ({} imgs in {:.2}s, {} fused steps, \
         {} KiB arena, energy ratio {:.1}x analytic)",
        acc_int,
        test.len(),
        t0.elapsed().as_secs_f64(),
        plan.num_steps(),
        plan.arena_bytes() / 1024,
        model.cost_report(1)?.energy_ratio()
    );

    std::fs::create_dir_all("results").ok();
    symog_run.outcome.log.save_csv(Path::new("results/lenet_mnist_symog.csv"))?;
    if let Some(t) = &symog_run.outcome.tracker {
        std::fs::write("results/lenet_mnist_switches.csv", t.to_csv())?;
    }
    symog_run
        .final_ckpt
        .write(Path::new("results/lenet_mnist_symog.ckpt"))
        .context("saving checkpoint")?;
    println!("logs -> results/lenet_mnist_symog.csv, checkpoint -> results/lenet_mnist_symog.ckpt");
    Ok(())
}
