//! The fixed-point payoff (paper section 3.1): train LeNet-5 with SYMOG,
//! hard-quantize, then run the PURE INTEGER inference engine — ternary
//! mantissas, i32 accumulators, bit-shift rescaling, zero multiplications
//! in conv/dense — and compare accuracy + energy against the float model.
//!
//!     make artifacts && cargo run --release --example fixedpoint_infer

use anyhow::{Context, Result};
use symog::config::Experiment;
use symog::data::Preset;
use symog::driver::{self, artifacts_root};
use symog::inference::IntModel;
use symog::runtime::Runtime;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let rt = Runtime::cpu()?;
    let exp = Experiment {
        name: "fx-infer".into(),
        artifact: "lenet5-symog-synth-mnist-w1-b2".into(),
        dataset: Preset::SynthMnist,
        train_n: if fast { 1024 } else { 4096 },
        test_n: if fast { 256 } else { 512 },
        epochs: if fast { 4 } else { 12 },
        ..Default::default()
    };
    let artifact = driver::load_artifact(&rt, &exp, &artifacts_root())
        .context("run `make artifacts` first")?;
    let (train, test) = exp.dataset.load(exp.train_n, exp.test_n, exp.seed);

    println!("=== SYMOG training ({} epochs) ===", exp.epochs);
    let result = driver::run_experiment(&artifact, &exp, &train, &test)?;
    let last = result.outcome.log.last().unwrap();
    println!("evalq (XLA float simulation of Q(w)): acc {:.4}", last.testq_acc);

    println!("\n=== pure integer inference (compile-then-execute) ===");
    let model = IntModel::build(&artifact.manifest, &result.final_ckpt)?;
    println!(
        "quantized params: {}   all-ternary: {}   (ternary ⇒ conv/dense have NO multiplies)",
        model.quant_params, model.all_ternary
    );
    // the same plan forward()/accuracy() use internally, shown explicitly:
    // shapes resolved, epilogues fused, arena preallocated — once
    let plan = model.shared_plan(64)?;
    println!(
        "execution plan: {} fused steps, {} KiB activation arena, {} workers",
        plan.num_steps(),
        plan.arena_bytes() / 1024,
        plan.workers()
    );
    let t0 = std::time::Instant::now();
    let acc = model.accuracy(&test.images, &test.labels, 64)?;
    println!(
        "integer-engine acc {:.4} vs evalq {:.4} (gap {:+.4}) — {} imgs in {:.2}s",
        acc,
        last.testq_acc,
        acc - last.testq_acc,
        test.len(),
        t0.elapsed().as_secs_f64()
    );

    println!("\n=== cost model (45nm energy, Sze et al. 2017 / Horowitz) ===");
    // analytic since the plan refactor: op counts come from shapes x
    // sparsity recorded at quantize time — no dummy forward runs here
    let report = model.cost_report(1)?;
    println!("{}", report.render());
    println!(
        "\npaper's motivating claim: 8-bit fixed mult is 18.5x cheaper than fp32;\n\
         ternary SYMOG inference measures {:.1}x cheaper end-to-end on this model.",
        report.energy_ratio()
    );
    Ok(())
}
