//! Closed-loop serving benchmark driver: N client threads hammer one
//! `serve::Server` with single-image requests and the wall clock is
//! compared against the same corpus pushed through solo batch-1 planned
//! forwards on one thread. Every served response is bit-identical to the
//! solo forward (spot-checked here; pinned exhaustively by
//! `tests/serve_conformance.rs` / `tests/serve_concurrency.rs`).
//!
//!     cargo run --release --example serve_bench -- \
//!         --model vgg7 --bits 2 --width 16 --clients 4 --requests 64 \
//!         --batch 8 --workers 0 --seed 1453 \
//!         --queue-depth 0 --deadline-ms 0 --faults ""
//!
//! `--workers 0` resolves to the host default (`SYMOG_WORKERS` honored).
//! Failure-domain knobs: `--queue-depth N` bounds admission (0 =
//! unbounded), `--deadline-ms N` attaches a deadline to every request
//! (0 = none) — refused/swept/failed requests are tallied, not fatal —
//! and `--faults site:prob:seed[,...]` arms the seeded injection sites
//! (requires a `--features fault-injection` build; same syntax as
//! `SYMOG_FAULTS`).
//!
//! `--tcp` routes every client request through the TCP front-end
//! (`serve::net`) on an ephemeral loopback port instead of calling the
//! in-process API, so the benchmark measures the full wire path: frame
//! encode → socket → decode → `infer_with` → encode → socket. The final
//! stats line is then read over the wire too (a Stats frame).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};
use symog::cli::Args;
use symog::inference::IntModel;
use symog::serve::net::{Client, TcpFront};
use symog::serve::{InferOpts, ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::util::fault;
use symog::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&["tcp"])?;
    let model_name = args.str_or("model", "vgg7");
    let bits = args.usize_or("bits", 2)? as u32;
    let width = args.usize_or("width", 16)?;
    let clients = args.usize_or("clients", 4)?.max(1);
    let requests = args.usize_or("requests", 64)?.max(1);
    let batch = args.usize_or("batch", 8)?.max(1);
    let workers = args.usize_or("workers", 0)?;
    let seed = args.u64_or("seed", 0x1453)?;
    let queue_depth = args.usize_or("queue-depth", 0)?;
    let deadline_ms = args.u64_or("deadline-ms", 0)?;
    let faults = args.str_or("faults", "");
    let tcp = args.switch("tcp");
    args.finish()?;

    if !faults.is_empty() {
        ensure!(
            fault::ENABLED,
            "--faults needs a fault-injection build: \
             cargo run --release --features fault-injection --example serve_bench"
        );
        arm_faults(&faults)?;
        println!("faults armed: {faults}");
    }

    let mut rng = Rng::new(seed);
    let (man, ck) = match model_name.as_str() {
        "vgg7" => models::vgg7ish(&mut rng, bits, width),
        "lenet5" => models::lenet5ish(&mut rng, bits),
        "densenet" => models::densenetish(&mut rng, bits),
        other => bail!("unknown --model {other:?} (vgg7|lenet5|densenet)"),
    };
    let model = IntModel::build(&man, &ck)?;
    let solo = IntModel::build(&man, &ck)?;
    let elems: usize = man.input_shape.iter().product();

    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(batch);
    let key = reg.add(&model_name, ModelSource::InCode(&model), &opts)?;
    let server =
        Arc::new(Server::new(reg, ServeConfig::new().workers(workers).queue_depth(queue_depth)));
    let front = if tcp { Some(TcpFront::bind(Arc::clone(&server), "127.0.0.1:0")?) } else { None };
    println!(
        "== serve_bench == model {key}  input {:?}  micro-batch cap {batch}  \
         clients {clients} x {requests} requests  queue depth {}  deadline {}{}",
        man.input_shape,
        if queue_depth == 0 { "unbounded".to_string() } else { queue_depth.to_string() },
        if deadline_ms == 0 { "none".to_string() } else { format!("{deadline_ms}ms") },
        match &front {
            Some(f) => format!("  via TCP {}", f.local_addr()),
            None => String::new(),
        },
    );

    // deterministic request corpus
    let total = clients * requests;
    let images: Vec<f32> = (0..total * elems).map(|_| rng.normal()).collect();

    // --- solo baseline: one thread, batch-1 planned forwards -------------
    let plan = solo.shared_plan(batch)?;
    println!(
        "plan: {} fused steps, {} KiB full-batch arena ({} B per row scratch)",
        plan.num_steps(),
        plan.arena_bytes() / 1024,
        plan.scratch_for(1).arena_bytes()
    );
    let mut scratch = plan.scratch_for(1);
    let mut out = vec![0f32; plan.out_per_img()];
    let t0 = Instant::now();
    for r in 0..total {
        plan.run_into(&images[r * elems..(r + 1) * elems], 1, &mut scratch, &mut out)?;
        std::hint::black_box(&out);
    }
    let solo_s = t0.elapsed().as_secs_f64();

    // --- served: closed-loop client threads ------------------------------
    // with deadlines/faults armed, refusals are expected outcomes: tally
    // them and let the stats line show the exact failure-domain split
    let served = AtomicU64::new(0);
    let refused = AtomicU64::new(0);
    let addr = front.as_ref().map(|f| f.local_addr());
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for t in 0..clients {
            let (server, key, images, served, refused, name) =
                (&server, &key, &images, &served, &refused, model_name.as_str());
            sc.spawn(move || {
                // one TCP connection per client thread, like a real client
                let mut wire = addr.map(|a| Client::connect(a).expect("connecting to front-end"));
                for i in 0..requests {
                    let r = t * requests + i;
                    let image = &images[r * elems..(r + 1) * elems];
                    let outcome = match &mut wire {
                        Some(c) => {
                            c.infer_with(name, bits, image, deadline_ms as u32, 0).map(|_| ())
                        }
                        None => {
                            let iopts = if deadline_ms == 0 {
                                InferOpts::new()
                            } else {
                                InferOpts::new().deadline_in(Duration::from_millis(deadline_ms))
                            };
                            server.infer_with(key, image, &iopts).map(|got| {
                                std::hint::black_box(got);
                            })
                        }
                    };
                    match outcome {
                        Ok(()) => {
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            refused.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let serve_s = t0.elapsed().as_secs_f64();
    let served = served.into_inner();
    let refused = refused.into_inner();
    ensure!(served + refused == total as u64, "a request vanished without a terminal outcome");

    // --- bit-exactness spot check ----------------------------------------
    for r in [0usize, total / 2, total - 1] {
        let img = &images[r * elems..(r + 1) * elems];
        let got = server.infer(&key, img)?;
        let (want, _) = solo.forward(img, 1)?;
        ensure!(got == want, "request {r}: served logits diverged from solo forward");
    }
    println!("bit-exactness: served logits == solo planned forwards (spot checks passed)");

    let stats = server.stats(&key)?;
    println!("stats: {}", stats.render());
    if let Some(front) = front {
        // read the same numbers back over the wire, like a remote
        // operator would, then close up shop
        let mut c = Client::connect(front.local_addr())?;
        let s = c.stats(&model_name, bits)?;
        println!(
            "wire  : v{}  {} requests  latency p50 {}us p99 {}us max {}us ({} samples)",
            s.version, s.requests, s.p50_us, s.p99_us, s.max_us, s.latency_count
        );
        drop(c);
        front.shutdown();
    }
    println!(
        "solo   : {total} requests in {solo_s:.3}s  ({:.1} req/s)",
        total as f64 / solo_s
    );
    println!(
        "served : {served} ok + {refused} refused in {serve_s:.3}s  ({:.1} req/s)  \
         -> {:.2}x vs solo",
        served as f64 / serve_s,
        solo_s / serve_s
    );
    Ok(())
}

/// Arm `--faults`; compiled only when the registry exists so the example
/// still builds (and the flag still errors cleanly) without the feature.
#[cfg(feature = "fault-injection")]
fn arm_faults(spec: &str) -> Result<()> {
    fault::arm_from_spec(spec)
}

#[cfg(not(feature = "fault-injection"))]
fn arm_faults(_spec: &str) -> Result<()> {
    unreachable!("gated by fault::ENABLED above")
}
