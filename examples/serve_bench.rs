//! Closed-loop serving benchmark driver: N client threads hammer one
//! `serve::Server` with single-image requests and the wall clock is
//! compared against the same corpus pushed through solo batch-1 planned
//! forwards on one thread. Every served response is bit-identical to the
//! solo forward (spot-checked here; pinned exhaustively by
//! `tests/serve_conformance.rs` / `tests/serve_concurrency.rs`).
//!
//!     cargo run --release --example serve_bench -- \
//!         --model vgg7 --bits 2 --width 16 --clients 4 --requests 64 \
//!         --batch 8 --workers 0 --seed 1453
//!
//! `--workers 0` resolves to the host default (`SYMOG_WORKERS` honored).

use std::time::Instant;

use anyhow::{bail, ensure, Result};
use symog::cli::Args;
use symog::inference::IntModel;
use symog::serve::{ModelSource, RegisterOpts, Registry, ServeConfig, Server};
use symog::testing::models;
use symog::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let model_name = args.str_or("model", "vgg7");
    let bits = args.usize_or("bits", 2)? as u32;
    let width = args.usize_or("width", 16)?;
    let clients = args.usize_or("clients", 4)?.max(1);
    let requests = args.usize_or("requests", 64)?.max(1);
    let batch = args.usize_or("batch", 8)?.max(1);
    let workers = args.usize_or("workers", 0)?;
    let seed = args.u64_or("seed", 0x1453)?;
    args.finish()?;

    let mut rng = Rng::new(seed);
    let (man, ck) = match model_name.as_str() {
        "vgg7" => models::vgg7ish(&mut rng, bits, width),
        "lenet5" => models::lenet5ish(&mut rng, bits),
        "densenet" => models::densenetish(&mut rng, bits),
        other => bail!("unknown --model {other:?} (vgg7|lenet5|densenet)"),
    };
    let model = IntModel::build(&man, &ck)?;
    let solo = IntModel::build(&man, &ck)?;
    let elems: usize = man.input_shape.iter().product();

    let mut reg = Registry::new();
    let opts = RegisterOpts::new().max_batch(batch);
    let key = reg.add(&model_name, ModelSource::InCode(&model), &opts)?;
    let server = Server::new(reg, ServeConfig { workers });
    println!(
        "== serve_bench == model {key}  input {:?}  micro-batch cap {batch}  \
         clients {clients} x {requests} requests",
        man.input_shape
    );

    // deterministic request corpus
    let total = clients * requests;
    let images: Vec<f32> = (0..total * elems).map(|_| rng.normal()).collect();

    // --- solo baseline: one thread, batch-1 planned forwards -------------
    let plan = solo.shared_plan(batch)?;
    println!(
        "plan: {} fused steps, {} KiB full-batch arena ({} B per row scratch)",
        plan.num_steps(),
        plan.arena_bytes() / 1024,
        plan.scratch_for(1).arena_bytes()
    );
    let mut scratch = plan.scratch_for(1);
    let mut out = vec![0f32; plan.out_per_img()];
    let t0 = Instant::now();
    for r in 0..total {
        plan.run_into(&images[r * elems..(r + 1) * elems], 1, &mut scratch, &mut out)?;
        std::hint::black_box(&out);
    }
    let solo_s = t0.elapsed().as_secs_f64();

    // --- served: closed-loop client threads ------------------------------
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for t in 0..clients {
            let (server, key, images) = (&server, &key, &images);
            sc.spawn(move || {
                for i in 0..requests {
                    let r = t * requests + i;
                    let got = server
                        .infer(key, &images[r * elems..(r + 1) * elems])
                        .expect("serve request failed");
                    std::hint::black_box(got);
                }
            });
        }
    });
    let serve_s = t0.elapsed().as_secs_f64();

    // --- bit-exactness spot check ----------------------------------------
    for r in [0usize, total / 2, total - 1] {
        let img = &images[r * elems..(r + 1) * elems];
        let got = server.infer(&key, img)?;
        let (want, _) = solo.forward(img, 1)?;
        ensure!(got == want, "request {r}: served logits diverged from solo forward");
    }
    println!("bit-exactness: served logits == solo planned forwards (spot checks passed)");

    let stats = server.stats(&key)?;
    println!("stats: {}", stats.render());
    println!(
        "solo   : {total} requests in {solo_s:.3}s  ({:.1} req/s)",
        total as f64 / solo_s
    );
    println!(
        "served : {total} requests in {serve_s:.3}s  ({:.1} req/s)  -> {:.2}x vs solo",
        total as f64 / serve_s,
        solo_s / serve_s
    );
    Ok(())
}
